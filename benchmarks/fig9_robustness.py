"""Paper Fig. 9 — robustness to light-tailed (exponential) exec times.

Expected reproduction: with homogeneous execution times all load-aware
schedulers converge; Hermes matches Least-Loaded / Late Binding, and
Vanilla OpenWhisk still suffers from skew.

Derives from fig6's batched sweep; the engine compile cache makes the
re-run nearly free.
"""
from __future__ import annotations

from .common import write_csv
from .fig6_slowdown import run as run_fig6


def run(quick: bool = True):
    rows = run_fig6(quick, workloads=("homogeneous-exec",), zoo=False)
    write_csv("fig9_robustness.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['scheduler']:13s} load={r['load']:.2f} "
              f"slow50={r['slow_p50']:7.2f} slow99={r['slow_p99']:9.1f}")
