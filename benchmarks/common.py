"""Shared benchmark helpers: load sweeps → CSV rows."""
from __future__ import annotations

import csv
import os
import time

from repro.core import ClusterCfg, PolicySpec, summarize_sim
from repro.core.simulator import simulate
from repro.core.sim_ref import simulate_ref

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments")


def sweep_policies(policies, cluster: ClusterCfg, loads, n_arrivals,
                   workload_fn, *, seed: int = 0, engine: str = "jax",
                   warmup_frac: float = 0.1):
    """Run every (policy × load) cell; returns list of dict rows."""
    rows = []
    for load in loads:
        wl = workload_fn(cluster, load, n_arrivals, seed)
        for pol in policies:
            t0 = time.time()
            if engine == "jax":
                out = simulate(pol, cluster, wl)
            else:
                out = simulate_ref(pol, cluster, wl)
            s = summarize_sim(out, wl, warmup_frac=warmup_frac)
            row = {"policy": pol.name, "load": load,
                   "wall_s": round(time.time() - t0, 2), **s.row()}
            rows.append(row)
    return rows


def write_csv(name: str, rows) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return path


def fmt_table(rows, cols) -> str:
    out = [" | ".join(f"{c:>12s}" for c in cols)]
    for r in rows:
        out.append(" | ".join(
            f"{r[c]:12.3f}" if isinstance(r[c], float) else f"{str(r[c]):>12s}"
            for c in cols))
    return "\n".join(out)
