"""Shared benchmark helpers: batched load sweeps → CSV rows.

The sweep is the batched-engine fast path: all load points of a sweep
share one ``(N, F)`` shape, so per policy they are stacked into a single
:class:`~repro.core.workload.WorkloadBatch` and run through one
``vmap``-ed compiled program (:func:`repro.core.simulator.simulate_many`).
The engine compile cache keys on ``(policy, cluster, N, F)``, so repeated
sweeps (e.g. fig7/8/9 re-deriving fig6 rows) re-use compiled programs.

``reps > 1`` replicates every load point over consecutive seeds inside
the same batch; rows then carry ``*_mean`` / ``*_ci95`` columns from
:class:`~repro.core.metrics.BatchSummary`.

Two registry-driven helpers close the loop with :mod:`repro.policy`:
:func:`registry_policies` expands a figure's base policy list with
``E/<B>/<sched>`` for *every* registered balancer (so new zoo entries are
swept by every figure without touching it), and
:func:`mixed_workload_batch` / :func:`sweep_policies_mixed` stack
heterogeneous ``WORKLOADS`` entries — synthetic §6.1 generators *and*
``azure-*`` trace replays — onto one ``simulate_many`` batch via
:func:`repro.trace.replay.resample_workloads`.
"""
from __future__ import annotations

import csv
import os
import time

from repro.core import (ClusterCfg, replicate_workload,
                        summarize_batch_sim, summarize_sim)
from repro.core.simulator import simulate_many
from repro.core.sim_ref import simulate_ref

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments")


def registry_policies(base=(), sched="PS"):
    """``base`` plus ``E/<B>/<sched>`` for every registered balancer.

    Policies already present in ``base`` (by name) are not duplicated,
    so figure sweeps keep their historical row set and grow a row per
    *new* registry entry — ``register_balancer`` is enough to appear in
    fig2/4/6/11.
    """
    from repro.core.taxonomy import Binding, PolicySpec
    from repro.policy import balancer_names
    pols = list(base)
    seen = {p.name for p in pols}
    for bname in balancer_names():
        cand = PolicySpec(Binding.EARLY, bname, sched)
        if cand.name not in seen:
            pols.append(cand)
            seen.add(cand.name)
    return tuple(pols)


def sweep_policies(policies, cluster: ClusterCfg, loads, n_arrivals,
                   workload_fn, *, seed: int = 0, engine: str = "jax",
                   warmup_frac: float = 0.1, reps: int = 1,
                   backend: str = "auto"):
    """Run every (policy × load [× rep]) cell; returns list of dict rows.

    ``engine="jax"`` batches all ``len(loads) × reps`` replications per
    policy into one ``simulate_many`` call; ``engine="ref"`` falls back to
    the per-cell numpy oracle (slow, for cross-checks).  ``backend``
    picks the selection backend of the batched engine (results are
    backend-invariant by the parity contract; ``"jax"`` skips
    interpret-mode kernel dispatch on huge clusters).
    """
    if engine != "jax":
        if reps > 1:
            raise ValueError("reps > 1 is only supported by the batched "
                             "jax engine")
        rows = []
        for load in loads:
            wl = workload_fn(cluster, load, n_arrivals, seed)
            for pol in policies:
                t0 = time.time()
                out = simulate_ref(pol, cluster, wl)
                s = summarize_sim(out, wl, warmup_frac=warmup_frac)
                rows.append({"policy": pol.name, "load": load,
                             "wall_s": round(time.time() - t0, 2),
                             **s.row()})
        return rows

    seeds = tuple(range(seed, seed + reps))
    wb = replicate_workload(workload_fn, cluster, loads, n_arrivals,
                            seeds=seeds)
    rows = []
    for pol in policies:
        t0 = time.time()
        out = simulate_many(pol, cluster, wb, backend=backend)
        cell_s = (time.time() - t0) / len(loads)
        for li, load in enumerate(loads):
            sl = slice(li * reps, (li + 1) * reps)
            bs = summarize_batch_sim(out[sl], wb[sl],
                                     warmup_frac=warmup_frac)
            # reps>1 adds the *_mean/*_ci95 columns of BatchSummary.row()
            cols = bs.row() if reps > 1 else bs.pooled.row()
            rows.append({"policy": pol.name, "load": load,
                         "wall_s": round(cell_s, 3), **cols})
    # interleave back to the historical (load-major) row order; the
    # precomputed load → first-index map replaces the per-row
    # list.index() scan (O(P·L²) overall → O(P·L·log(P·L))).  Duplicate
    # load values share one key either way; the stable sort keeps their
    # rows in generation order.
    load_order = {}
    for i, load in enumerate(loads):
        load_order.setdefault(load, i)
    rows.sort(key=lambda r: load_order[r["load"]])
    return rows


def mixed_workload_batch(cluster: ClusterCfg, names, load, n_arrivals,
                         *, seed: int = 0):
    """Stack heterogeneous ``WORKLOADS`` entries into ONE batch.

    ``names`` mixes synthetic §6.1 generators with ``azure-*`` trace
    replays; the workloads disagree on function count (synthetics use
    50, replays carry per-trace ``F``), so they are harmonized through
    :func:`repro.trace.replay.resample_workloads` (truncate to the
    shortest ``N``, widen to the largest ``F``) and returned as a
    ``simulate_many``-ready :class:`~repro.core.workload.WorkloadBatch`
    whose replication ``r`` is ``names[r]`` — the ROADMAP
    mixed-batches item.
    """
    from repro.core import WORKLOADS
    from repro.trace.replay import resample_workloads
    wls = [WORKLOADS[name](cluster, load, n_arrivals, seed)
           for name in names]
    return resample_workloads(wls)


def sweep_policies_mixed(policies, cluster: ClusterCfg, names, load,
                         n_arrivals, *, seed: int = 0,
                         warmup_frac: float = 0.1, backend: str = "auto"):
    """Sweep policies over a mixed synthetic+replay batch.

    One ``simulate_many`` call per policy covers every named workload;
    rows carry a ``workload`` column (one row per (policy, name)).
    """
    wb = mixed_workload_batch(cluster, names, load, n_arrivals, seed=seed)
    rows = []
    for pol in policies:
        t0 = time.time()
        out = simulate_many(pol, cluster, wb, backend=backend)
        cell_s = (time.time() - t0) / len(names)
        for r, name in enumerate(names):
            s = summarize_sim(out.rep(r), wb.rep(r),
                              warmup_frac=warmup_frac)
            rows.append({"policy": pol.name, "workload": name,
                         "load": load, "wall_s": round(cell_s, 3),
                         **s.row()})
    return rows


def write_csv(name: str, rows) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return path


def fmt_table(rows, cols) -> str:
    out = [" | ".join(f"{c:>12s}" for c in cols)]
    for r in rows:
        out.append(" | ".join(
            f"{r[c]:12.3f}" if isinstance(r[c], float) else f"{str(r[c]):>12s}"
            for c in cols))
    return "\n".join(out)
