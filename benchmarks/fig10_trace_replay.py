"""Fig. 10 (repo extension) — Hermes vs. baselines under non-stationary
Azure-schema trace replay.

The paper's figures drive stationary Poisson stand-ins for the Azure
trace; this sweep replays *non-stationary* trace-shaped load — the
diurnal and bursty scenario presets of :mod:`repro.trace.synth_trace`,
reconstructed per-minute-count-exactly by :mod:`repro.trace.replay` —
through the same batched engine and §6 schedulers as fig6.  Every
(scenario × load) cell runs ``reps`` seed replications inside one
``simulate_many`` batch, so rows carry across-replication mean ± 95 % CI
columns (``slow_p99_mean`` / ``slow_p99_ci95``, ...).

Expected shape of the result: the diurnal/bursty peaks push instantaneous
load well above the long-run average, so locality-only placement
(vanilla OpenWhisk) degrades earlier than in fig6, while Hermes tracks
Least-Loaded's tail with fewer cold starts — the data-driven-scheduling
setting of Przybylski et al. and the pull/hybrid stress case of Hiku.
"""
from __future__ import annotations

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, LATE_BINDING,
                        PAPER_TESTBED, WORKLOADS)

from .common import sweep_policies, write_csv

SCHEDULERS = {"vanilla-ow": E_LOC_PS, "late-binding": LATE_BINDING,
              "least-loaded": E_LL_PS, "hermes": HERMES}
FIG10_SCENARIOS = ("azure-diurnal", "azure-bursty")


def run(quick: bool = True, *, scenarios=FIG10_SCENARIOS,
        cold_start_s: float = 0.5):
    loads = [0.5, 0.7] if quick else [0.3, 0.5, 0.7, 0.85]
    n = 3000 if quick else 12000
    reps = 3 if quick else 5
    cl = PAPER_TESTBED._replace(cold_start_penalty=cold_start_s)
    name_of = {pol.name: s for s, pol in SCHEDULERS.items()}
    rows = []
    for scen in scenarios:
        # all scenarios share (N, F) -> one compiled engine per policy
        scen_rows = sweep_policies(list(SCHEDULERS.values()), cl, loads, n,
                                   WORKLOADS[scen], reps=reps)
        for r in scen_rows:
            rows.append({"workload": scen,
                         "scheduler": name_of[r.pop("policy")], **r})
    write_csv("fig10_trace_replay.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:16s} {r['scheduler']:13s} "
              f"load={r['load']:.2f} "
              f"slow99={r['slow_p99_mean']:10.1f} ±{r['slow_p99_ci95']:8.1f} "
              f"cold%={100 * r['cold_frac_mean']:5.1f}")
