"""Paper Fig. 3 — SRPT (oracle exec times) vs PS, median + 99% slowdown.

Expected reproduction (Lesson 3): E/LL/SRPT beats E/LL/PS on *median*
slowdown at high load but loses on the 99% tail (long-request
starvation).

All load points run as one stacked batch per policy through the
``simulate_many`` engine (see :mod:`benchmarks.common`).
"""
from __future__ import annotations

from repro.core import E_LL_PS, E_LL_SRPT, PAPER_SMALL, ms_trace

from .common import sweep_policies, write_csv


def run(quick: bool = True):
    loads = [0.5, 0.7, 0.8, 0.9, 0.95] if quick else \
        [0.3, 0.4, 0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95]
    n = 8000 if quick else 20000
    rows = sweep_policies((E_LL_PS, E_LL_SRPT), PAPER_SMALL, loads, n,
                          ms_trace)
    write_csv("fig3_srpt.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['policy']:10s} load={r['load']:.2f} "
              f"slow50={r['slow_p50']:8.2f} slow99={r['slow_p99']:10.1f}")
