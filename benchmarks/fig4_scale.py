"""Paper Fig. 4 — 99% slowdown at scale (100 servers × 12 cores).

Expected reproduction (§3.5): E/R/PS and E/LOC/PS explode near 0.6 load;
Late Binding improves with scale (less head-of-line blocking) but
E/LL/PS still wins at very high load (>0.96).

The sweep additionally covers ``E/<B>/PS`` for every registry balancer
— W=100 is where the zoo gets interesting (HIKU's ready-ring almost
always holds an idle worker; JSQ2's two samples approximate full LL
information at 1/50th the state reads).

All load points run as one stacked batch per policy through the
``simulate_many`` engine.  Selection uses the pure-jax backend: at
W=100 the interpret-mode Pallas path (the `auto` pick for Hermes off-
TPU) only adds compile time, and results are backend-invariant by the
parity contract.
"""
from __future__ import annotations

from repro.core import (E_LL_PS, E_LOC_PS, E_R_PS, LATE_BINDING,
                        PAPER_LARGE, ms_trace)

from .common import registry_policies, sweep_policies, write_csv

POLICIES = (E_R_PS, E_LOC_PS, LATE_BINDING, E_LL_PS)


def run(quick: bool = True):
    loads = [0.5, 0.7, 0.9, 0.97] if quick else \
        [0.3, 0.5, 0.6, 0.7, 0.8, 0.9, 0.94, 0.96, 0.98]
    n = 12000 if quick else 40000
    rows = sweep_policies(registry_policies(POLICIES), PAPER_LARGE, loads,
                          n, ms_trace, backend="jax")
    write_csv("fig4_scale.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['policy']:10s} load={r['load']:.2f} "
              f"slow99={r['slow_p99']:10.1f}")
