"""Telemetry sketch-accuracy gate: streaming vs exact percentiles.

For every registered balancer at loads {0.3, 0.6, 0.8}, runs the batched
engine with in-scan telemetry and compares the histogram-sketch p50/p99
slowdown against the exact :func:`repro.core.metrics.summarize_batch`
pooled percentiles over the materialized per-task arrays.  The
REPRO-CHECK in :mod:`benchmarks.run` gates on ≤ ``TOL_REL`` relative
error — the documented sketch tolerance (half-bin geometric error
≈ 0.76 % for 1536 bins over 10 decades, plus rank-interpolation slack;
see :mod:`repro.telemetry.sketch`).

This is the streaming-engine precondition proven end to end: the same
numbers the figures report from materialized arrays, read instead from
a fixed-size sketch carried through the scan.

A second lane (``lane="overhead"``) gates the cost of the windowed
timeline plane (:mod:`repro.telemetry.timeline`): one steady-state
compiled dispatch with telemetry only versus telemetry + timeline
(min of :data:`OVERHEAD_REPS` runs each); the REPRO-CHECK requires the
flight recorder to add at most :data:`TOL_TL_OVERHEAD` relative wall
(plus a small absolute slack so sub-second runs aren't gated on timer
noise).
"""
from __future__ import annotations

import time

from repro.core import E_LL_PS
from repro.core.cluster import ClusterCfg
from repro.core.metrics import summarize_batch_sim
from repro.core.simulator import simulate, simulate_many
from repro.core.workload import ms_trace, stack_workloads
from repro.telemetry import TelemetryCfg, TimelineCfg

from .common import registry_policies, write_csv

LOADS = (0.3, 0.6, 0.8)
#: documented sketch tolerance (relative error vs np.percentile)
TOL_REL = 0.02
#: max relative steady-state wall the timeline plane may add on top of
#: telemetry-only (plus OVERHEAD_SLACK_S absolute)
TOL_TL_OVERHEAD = 0.05
OVERHEAD_SLACK_S = 0.05
OVERHEAD_REPS = 3


def _rel_err(sketch: float, exact: float) -> float:
    return abs(sketch - exact) / max(abs(exact), 1e-12)


def run(quick: bool = True) -> list[dict]:
    cluster = ClusterCfg(n_workers=8, cores=8)
    n = 6000 if quick else 60000
    reps = 2 if quick else 5
    warmup = 0.1
    tel_cfg = TelemetryCfg(warmup_frac=warmup)
    rows: list[dict] = []
    for spec in registry_policies():
        for load in LOADS:
            wls = [ms_trace(cluster, load, n, seed=17 + r)
                   for r in range(reps)]
            wb = stack_workloads(wls)
            out = simulate_many(spec, cluster, wb, telemetry=tel_cfg)
            exact = summarize_batch_sim(out, wb,
                                        warmup_frac=warmup).pooled
            tel = out.telemetry
            s50, s99 = tel.slow_percentile(50), tel.slow_percentile(99)
            e50, e99 = _rel_err(s50, exact.slow_p50), \
                _rel_err(s99, exact.slow_p99)
            rows.append({
                "lane": "sketch",
                "policy": spec.name, "load": load, "n": n, "reps": reps,
                "sketch_p50": round(s50, 6), "exact_p50":
                round(exact.slow_p50, 6),
                "sketch_p99": round(s99, 6), "exact_p99":
                round(exact.slow_p99, 6),
                "rel_err_p50": round(e50, 6), "rel_err_p99":
                round(e99, 6),
                "ok": bool(e50 <= TOL_REL and e99 <= TOL_REL),
            })
    rows.append(_overhead_row(cluster, n))
    cols = {k: None for r in rows for k in r}
    write_csv("bench_telemetry.csv",
              [{k: r.get(k, "") for k in cols} for r in rows])
    return rows


def _overhead_row(cluster: ClusterCfg, n: int) -> dict:
    """Steady-state wall: telemetry-only vs telemetry + timeline."""
    wl = ms_trace(cluster, 0.6, n, seed=29)
    tel = TelemetryCfg()

    def best_wall(timeline):
        # first call compiles (engine-cache miss); timed calls are
        # pure dispatch + host transfer
        simulate(E_LL_PS, cluster, wl, backend="jax", telemetry=tel,
                 timeline=timeline)
        best = float("inf")
        for _ in range(OVERHEAD_REPS):
            t0 = time.perf_counter()
            simulate(E_LL_PS, cluster, wl, backend="jax", telemetry=tel,
                     timeline=timeline)
            best = min(best, time.perf_counter() - t0)
        return best

    tel_wall = best_wall(None)
    tl_wall = best_wall(TimelineCfg())
    budget = tel_wall * (1.0 + TOL_TL_OVERHEAD) + OVERHEAD_SLACK_S
    return {
        "lane": "overhead", "policy": E_LL_PS.name, "load": 0.6,
        "n": n, "reps": OVERHEAD_REPS,
        "tel_wall_s": round(tel_wall, 6),
        "tl_wall_s": round(tl_wall, 6),
        "overhead_frac": round(tl_wall / tel_wall - 1.0, 6),
        "ok": bool(tl_wall <= budget),
    }


if __name__ == "__main__":
    for r in run():
        print(r)
