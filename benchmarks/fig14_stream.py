"""Fig. 14 (extension) — the horizon-scale streaming engine.

Two lanes gating the chunked-scan engine
(:func:`repro.core.streaming.simulate_stream`):

* **equivalence lane** — chunked ≡ monolithic, *bit for bit*.  For a
  registry-spanning set of engine stacks (every registered balancer on
  the plain cluster, least-loaded under every keep-alive policy, a
  speed-blind and a speed-learning balancer on a two-generation fleet,
  and the full DD + HYBRID_HIST + two-gen + TARGET_P99 stack) the
  chunked engine's final carry, per-arrival outputs, telemetry
  sketches and pooled metrics are compared bitwise against the
  monolithic scan at small N — including a chunk size that does not
  divide the horizon.  Two stacks additionally replay the numpy
  oracle's chunked reference (:func:`repro.core.sim_ref
  .simulate_ref_chunks`) and compare telemetry at every segment
  boundary, so a drift would be caught mid-run, not just at the end.
* **horizon lane** — one full synthetic ``azure-diurnal`` day at
  ``W ≥ 1000`` workers runs in ONE streaming call.  The kernel's
  peak-RSS high-water mark is reset before the run and recorded after
  (:func:`repro.telemetry.manifest.peak_rss_mb`); the REPRO-CHECK gate
  requires completion under :data:`PEAK_MB_BUDGET`.  Memory is
  horizon-independent — only the chunk, never ``(N,)``, is resident —
  so the same budget holds at any day length.

Every row carries ``lane`` / ``chunk`` / ``ok`` columns so
``BENCH_report.json`` can reconstruct both gates.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ClusterCfg, FleetCfg, LifecycleCfg, WORKLOADS,
                        stack_workloads, synth_workload)
from repro.core.simulator import build_batch_simulator
from repro.core.sim_ref import simulate_ref_chunks
from repro.core.streaming import final_states_equal, simulate_stream
from repro.core.taxonomy import Binding, PolicySpec
from repro.lifecycle.registry import keepalive_names
from repro.policy import balancer_names
from repro.telemetry import TelemetryCfg
from repro.telemetry.manifest import peak_rss_mb, reset_peak_rss

from .common import write_csv

# Equivalence lane: small horizon, two replications (different load and
# seed), chunk sizes chosen so the non-dividing tail-padding path is
# always exercised (240 % 96 != 0).
EQ_N = 240
EQ_CHUNKS = (96,)          # quick tier; full adds a dividing size
EQ_CHUNKS_FULL = (80, 96)
EQ_CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
EQ_LOADS = ((0.6, 0), (1.0, 1))    # (load, seed) per replication

# Horizon lane: one synthetic Azure-schema day on a large fleet.
HORIZON_W = 1000
HORIZON_CLUSTER = ClusterCfg(n_workers=HORIZON_W, cores=2,
                             capacity_factor=2)
HORIZON_WORKLOAD = "azure-diurnal"
HORIZON_LOAD = 0.7
HORIZON_CHUNK = 4096
#: Arrivals in the full-day horizon (~1/s over 24 h); quick mode runs a
#: shortened day through the identical engine and chunk size.
HORIZON_N = 86_400
HORIZON_N_QUICK = 12_000
#: Peak-RSS budget (MiB) for the full-day run — the horizon gate.
PEAK_MB_BUDGET = 4096.0


def equivalence_stacks():
    """(label, policy, cluster) per audited engine stack."""
    stacks = []
    for bname in balancer_names():
        pol = PolicySpec(Binding.EARLY, bname, "PS")
        stacks.append((f"{pol.name}", pol, EQ_CLUSTER))
    ll = PolicySpec(Binding.EARLY, "LL", "PS")
    for ka in keepalive_names():
        cl = EQ_CLUSTER._replace(lifecycle=LifecycleCfg(keepalive=ka))
        stacks.append((f"{ll.name}|ka={ka}", ll, cl))
    het = EQ_CLUSTER._replace(fleet=FleetCfg(preset="two-gen"))
    for bname in ("LL", "SWARM"):
        pol = PolicySpec(Binding.EARLY, bname, "PS")
        stacks.append((f"{pol.name}|fleet", pol, het))
    dd = PolicySpec(Binding.EARLY, "DD", "PS")
    full = EQ_CLUSTER._replace(
        lifecycle=LifecycleCfg(keepalive="HYBRID_HIST", ttl_s=2.0,
                               max_idle=3, coldstart="paper-sim"),
        fleet=FleetCfg(preset="two-gen", autoscale="TARGET_P99",
                       min_workers=2, target_p99=4.0, cooldown_s=2.0))
    stacks.append((f"{dd.name}|ka=HYBRID_HIST|fleet|auto", dd, full))
    return stacks


def _check_equivalence(policy, cluster, chunk, tel):
    """One stack × chunk: stream vs monolithic, bitwise.  Returns
    (ok, mismatched plane names)."""
    import jax.numpy as jnp

    wls = [synth_workload(cluster, load, EQ_N, n_functions=5, seed=seed)
           for load, seed in EQ_LOADS]
    wb = stack_workloads(wls)
    run = build_batch_simulator(policy, cluster, n_arrivals=wb.n,
                                n_functions=wb.n_functions,
                                backend="jax", telemetry=tel)
    mono = run(jnp.asarray(wb.arrival), jnp.asarray(wb.func),
               jnp.asarray(wb.service), jnp.asarray(wb.u_lb),
               jnp.asarray(wb.func_home))
    out = simulate_stream(policy, cluster, wb, chunk_size=chunk,
                          backend="jax", telemetry=tel,
                          collect_outputs=True, keep_final_state=True)
    ok, bad = final_states_equal(out.final_state, mono)
    for name, a, b in (
            ("rejected", out.rejected, mono.rejected[:, :wb.n]),
            ("cold", out.cold, mono.cold[:, :wb.n]),
            ("worker", out.worker, mono.worker_of[:, :wb.n])):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            ok = False
            bad.append(f"outputs.{name}")
    return ok, bad


def _check_oracle_segments(policy, cluster, chunk, tel):
    """Per-segment telemetry parity: jax chunk engine vs the numpy
    oracle's chunked replay, at every chunk boundary."""
    wl = synth_workload(cluster, 0.9, EQ_N, n_functions=5, seed=2)
    _, snaps = simulate_ref_chunks(policy, cluster, wl,
                                   chunk_size=chunk, telemetry=tel)
    seen = []
    simulate_stream(
        policy, cluster, wl, chunk_size=chunk, backend="jax",
        telemetry=tel,
        chunk_callback=lambda c, st: seen.append(
            {k: np.copy(np.asarray(v)[0]) for k, v in st.tel.items()}))
    if len(seen) != len(snaps):
        return False, [f"segments {len(seen)} != {len(snaps)}"]
    bad = []
    for i, (got, want) in enumerate(zip(seen, snaps)):
        for key in ("slow_hist", "lat_hist", "n_cold", "n_warm",
                    "n_evict", "n_reject", "decisions"):
            if not np.array_equal(got[key], want[key]):
                bad.append(f"seg{i}.{key}")
        for key in ("busy_time", "depth_time", "qlen_time"):
            if not np.allclose(got[key], want[key], atol=1e-9):
                bad.append(f"seg{i}.{key}")
    return (not bad, bad)


def _equivalence_lane(chunks):
    tel = TelemetryCfg()
    rows = []
    for label, policy, cluster in equivalence_stacks():
        for chunk in chunks:
            t0 = time.time()
            ok, bad = _check_equivalence(policy, cluster, chunk, tel)
            rows.append({
                "lane": "equivalence", "stack": label, "chunk": chunk,
                "n_arrivals": EQ_N, "n_reps": len(EQ_LOADS),
                "ok": bool(ok), "mismatches": ";".join(bad),
                "wall_s": round(time.time() - t0, 3)})
    # mid-run drift guard: oracle parity at every segment boundary for
    # a plain stack and the heaviest lifecycle stack
    ll = PolicySpec(Binding.EARLY, "LL", "PS")
    hyb = EQ_CLUSTER._replace(
        lifecycle=LifecycleCfg(keepalive="HYBRID_HIST"))
    for label, policy, cluster in (("E/LL/PS|oracle-seg", ll, EQ_CLUSTER),
                                   ("E/LL/PS|ka=HYBRID_HIST|oracle-seg",
                                    ll, hyb)):
        t0 = time.time()
        ok, bad = _check_oracle_segments(policy, cluster, chunks[0], tel)
        rows.append({
            "lane": "equivalence", "stack": label, "chunk": chunks[0],
            "n_arrivals": EQ_N, "n_reps": 1, "ok": bool(ok),
            "mismatches": ";".join(bad),
            "wall_s": round(time.time() - t0, 3)})
    return rows


def _horizon_lane(quick):
    from repro.core import E_LL_PS
    n = HORIZON_N_QUICK if quick else HORIZON_N
    tel = TelemetryCfg()
    wl = WORKLOADS[HORIZON_WORKLOAD](HORIZON_CLUSTER, HORIZON_LOAD, n,
                                     seed=1)
    reset_peak_rss()
    t0 = time.time()
    out = simulate_stream(E_LL_PS, HORIZON_CLUSTER, wl,
                          chunk_size=HORIZON_CHUNK, backend="jax",
                          telemetry=tel)
    wall = time.time() - t0
    peak = peak_rss_mb()
    done = int(out.n_done.sum())
    ok = done > 0 and peak <= PEAK_MB_BUDGET
    return [{
        "lane": "horizon", "stack": "E/LL/PS", "workload":
        HORIZON_WORKLOAD, "n_workers": HORIZON_W, "n_arrivals": n,
        "chunk": HORIZON_CHUNK, "n_chunks": out.n_chunks,
        "n_done": done,
        "slow_p99": float(out.telemetry.slow_percentile(99.0)),
        "peak_rss_mb": round(peak, 1),
        "peak_mb_budget": PEAK_MB_BUDGET,
        "full_day": not quick, "ok": bool(ok),
        "wall_s": round(wall, 3)}]


def run(quick: bool = True):
    rows = _equivalence_lane(EQ_CHUNKS if quick else EQ_CHUNKS_FULL)
    rows += _horizon_lane(quick)
    # the two lanes carry different columns; pad to the union so one
    # CSV holds both
    cols = {k: None for r in rows for k in r}
    write_csv("fig14_stream.csv",
              [{k: r.get(k, "") for k in cols} for r in rows])
    return rows


if __name__ == "__main__":
    for r in run():
        extra = (f"peak={r['peak_rss_mb']:.0f}MiB "
                 f"n={r['n_arrivals']}" if r["lane"] == "horizon"
                 else f"chunk={r['chunk']} {r['mismatches'] or 'bitwise'}")
        print(f"{r['lane']:12s} {r['stack']:34s} "
              f"{'OK ' if r['ok'] else 'BAD'} {extra}")
